"""Fused ALSH probe tail: scalar-prefetch gather + exact re-rank + top-k.

The unfused tail (`index.data[ids]` → wl1_rerank → lax.top_k) materializes a
(b, L·C, d) candidate tensor in HBM and reads it straight back — for the
standard b=64, L·C=4096, d=128 probe that is two full passes over 128 MB the
query never needed. This kernel removes it: candidate ids are handed to
Pallas as **scalar-prefetch** arguments (`pltpu.PrefetchScalarGridSpec`), so
the BlockSpec index map — evaluated ahead of the grid step — points the
pipeline's DMA engine directly at the needed `(1, d-chunk)` row of the
(n, d) table in HBM. Each candidate's weighted |diff| partial sums accumulate
in a scalar scratch across d-chunks; the finished distance is folded into a
per-query VMEM top-k buffer by replace-max insertion:

  grid (query i, candidate j, d-chunk kd):
    data block  (1, BDR)  @ row  min(ids[i, j], n-1)   — the gather
    out blocks  (1, KP)   @ i                          — running top-k

Invalid candidates (padding, duplicates zapped by dedupe) carry the sentinel
id n: the index map clamps them to a readable row and the merge step drops
them. The buffer holds the KP (=128-aligned) smallest distances unsorted; the
wrapper sorts the (b, KP) result and slices (b, k) — exactly the oracle's
`ref.gather_rerank_topk` semantics ((+inf, -1) tails when fewer than k valid).

The CPU production path (`gather_rerank_topk_auto`) fuses in pure jnp and
picks its schedule by static footprint: a monolithic single-pass (one XLA
fusion region, no inter-stage materialization) while the (b, P, d) working
set is cache-resident, switching to `gather_rerank_topk_chunked` — a
fori_loop over candidate chunks (gather chunk → re-rank → top-k merge) that
keeps the live set at O(b·chunk·d) and skips all-sentinel chunks — once the
monolith would spill.

Two-segment mode (`delta=` on every entry point): a mutable index re-ranks
against a sealed (n_main, d) main table PLUS an unsealed (cap, d) delta
table, with candidate ids addressing their virtual concatenation (id i >=
n_main is delta slot i - n_main). Rather than concatenating the tables per
query batch — an O((n_main + cap)·d) HBM copy the old two-segment tail
paid — every schedule gathers from whichever segment owns each id: the
Pallas kernel runs BOTH tables as scalar-prefetch gather streams (the
index maps clamp each id into its own segment; the kernel keeps the
partial sum of the owning segment), and the jnp schedules select per
candidate between two clamped row gathers. Bit-identical to the
concatenated-table result.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BDR = 128  # coordinates per d-chunk (gather DMA granularity)
KP_LANE = 128  # top-k buffer lane alignment


def _gather_rerank_kernel(ids_ref, row_ref, q_ref, w_ref, outd_ref, outi_ref, acc_ref, *, n: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    kd = pl.program_id(2)
    nd = pl.num_programs(2)

    @pl.when((j == 0) & (kd == 0))
    def _init_topk():
        outd_ref[...] = jnp.full_like(outd_ref, jnp.inf)
        outi_ref[...] = jnp.full_like(outi_ref, -1)

    partial = jnp.sum(w_ref[...] * jnp.abs(row_ref[...] - q_ref[...]))  # scalar

    @pl.when(kd == 0)
    def _acc_init():
        acc_ref[0, 0] = partial

    @pl.when(kd != 0)
    def _acc():
        acc_ref[0, 0] += partial

    @pl.when(kd == nd - 1)
    def _merge():
        cid = ids_ref[i, j]
        dist = acc_ref[0, 0]
        cur_d = outd_ref[...]  # (1, KP)
        cur_i = outi_ref[...]
        worst = jnp.max(cur_d)
        slot = jnp.argmax(cur_d)  # first-occurrence ⇒ fills +inf slots in order

        @pl.when((cid < n) & (dist < worst))
        def _insert():
            lane = jax.lax.broadcasted_iota(jnp.int32, cur_d.shape, 1)
            put = lane == slot
            outd_ref[...] = jnp.where(put, dist, cur_d)
            outi_ref[...] = jnp.where(put, cid, cur_i)


def _gather_rerank2_kernel(
    ids_ref, main_ref, delta_ref, q_ref, w_ref, outd_ref, outi_ref, acc_ref,
    *, n_main: int, n_tot: int,
):
    """Two-segment variant: the grid pipelines BOTH segment tables as
    scalar-prefetch gather streams (each index map clamps the candidate id
    into its own segment), and the accumulator keeps whichever partial sum
    belongs to the segment that owns the id — the merge step is unchanged."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    kd = pl.program_id(2)
    nd = pl.num_programs(2)

    @pl.when((j == 0) & (kd == 0))
    def _init_topk():
        outd_ref[...] = jnp.full_like(outd_ref, jnp.inf)
        outi_ref[...] = jnp.full_like(outi_ref, -1)

    cid = ids_ref[i, j]
    part_m = jnp.sum(w_ref[...] * jnp.abs(main_ref[...] - q_ref[...]))  # scalar
    part_d = jnp.sum(w_ref[...] * jnp.abs(delta_ref[...] - q_ref[...]))
    partial = jnp.where(cid < n_main, part_m, part_d)

    @pl.when(kd == 0)
    def _acc_init():
        acc_ref[0, 0] = partial

    @pl.when(kd != 0)
    def _acc():
        acc_ref[0, 0] += partial

    @pl.when(kd == nd - 1)
    def _merge():
        dist = acc_ref[0, 0]
        cur_d = outd_ref[...]  # (1, KP)
        cur_i = outi_ref[...]
        worst = jnp.max(cur_d)
        slot = jnp.argmax(cur_d)  # first-occurrence ⇒ fills +inf slots in order

        @pl.when((cid < n_tot) & (dist < worst))
        def _insert():
            lane = jax.lax.broadcasted_iota(jnp.int32, cur_d.shape, 1)
            put = lane == slot
            outd_ref[...] = jnp.where(put, dist, cur_d)
            outi_ref[...] = jnp.where(put, cid, cur_i)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def gather_rerank_topk_pallas(
    data: jax.Array,
    ids: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
    *,
    delta: jax.Array | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """data (n, d), ids (b, P) int32 (>= n ⇒ invalid), queries/weights (b, d)
    -> ((b, k) ascending dists, (b, k) ids). With ``delta`` (cap, d), ids
    address the virtual [data; delta] concatenation (never materialized)."""
    n, d = data.shape
    b, P = ids.shape
    kp = -min(k, P) % KP_LANE + min(k, P)
    pd = -d % BDR
    data_p = jnp.pad(data.astype(jnp.float32), ((0, 0), (0, pd)))
    q_p = jnp.pad(queries.astype(jnp.float32), ((0, 0), (0, pd)))
    w_p = jnp.pad(weights.astype(jnp.float32), ((0, 0), (0, pd)))
    dp = d + pd
    grid = (b, P, dp // BDR)
    row_spec = pl.BlockSpec(
        (1, BDR), lambda i, j, kd, ids_ref: (jnp.minimum(ids_ref[i, j], n - 1), kd)
    )
    qw_spec = pl.BlockSpec((1, BDR), lambda i, j, kd, ids_ref: (i, kd))
    out_spec = pl.BlockSpec((1, kp), lambda i, j, kd, ids_ref: (i, 0))
    if delta is None:
        in_specs = [row_spec, qw_spec, qw_spec]
        kernel = functools.partial(_gather_rerank_kernel, n=n)
        tables = (data_p,)
    else:
        cap = delta.shape[0]
        # round delta rows through the main table's dtype first — the same
        # cast every other schedule (and the old concat path) applies, so
        # mixed-dtype segments rerank identically across backends
        delta_p = jnp.pad(delta.astype(data.dtype).astype(jnp.float32), ((0, 0), (0, pd)))
        delta_spec = pl.BlockSpec(
            (1, BDR),
            lambda i, j, kd, ids_ref: (jnp.clip(ids_ref[i, j] - n, 0, cap - 1), kd),
        )
        in_specs = [row_spec, delta_spec, qw_spec, qw_spec]
        kernel = functools.partial(_gather_rerank2_kernel, n_main=n, n_tot=n + cap)
        tables = (data_p, delta_p)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=(out_spec, out_spec),
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.float32)],
    )
    out_d, out_i = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((b, kp), jnp.float32),
            jax.ShapeDtypeStruct((b, kp), jnp.int32),
        ),
        interpret=interpret,
    )(ids.astype(jnp.int32), *tables, q_p, w_p)
    # buffer is the kp smallest, unsorted — order + trim to k outside the kernel
    from repro.kernels.ref import _topk_ascending

    return _topk_ascending(out_d, out_i, k)


# Above this candidate-tensor footprint (b·P·d·4 bytes) the one-shot XLA
# fusion starts spilling LLC on CPU and the chunked streaming schedule wins
# (measured crossover between 16 MB and 32 MB on x86; see BENCH_kernels.json).
MONOLITH_BYTES = 24 * 1024 * 1024


@functools.partial(jax.jit, static_argnames=("k",))
def _gather_rerank_topk_monolith(
    data: jax.Array,
    ids: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
    delta: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """One-shot fused tail: same math as the oracle but inside a single jit
    region, so XLA folds gather → re-rank → top-k into one pass with no
    inter-stage materialization. Best schedule while the candidate tensor
    stays cache-resident."""
    from repro.kernels import ref

    if delta is None:
        return ref.gather_rerank_topk(data, ids, queries, weights, k)
    return ref.gather_rerank_topk_segmented(data, delta, ids, queries, weights, k)


def gather_rerank_topk_auto(
    data: jax.Array,
    ids: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
    delta: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """CPU production dispatch: pick the fused schedule by static footprint —
    monolithic single-pass when the (b, P, d) working set fits on-chip,
    chunked streaming (skip-capable) when it would spill. The two-segment
    monolith materializes both per-segment gathers plus their select (~3x
    the single-segment working set), so its budget is scaled to match."""
    b, P = ids.shape
    d = data.shape[1]
    working_set = b * P * d * 4 * (3 if delta is not None else 1)
    if working_set <= MONOLITH_BYTES:
        return _gather_rerank_topk_monolith(data, ids, queries, weights, k, delta=delta)
    return gather_rerank_topk_chunked(data, ids, queries, weights, k, delta=delta)


@functools.partial(jax.jit, static_argnames=("k", "chunk"))
def gather_rerank_topk_chunked(
    data: jax.Array,
    ids: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
    chunk: int = 256,
    delta: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Pure-jnp fused tail (CPU production path): chunked gather → re-rank →
    streaming top-k merge. Never materializes the (b, P, d) tensor.

    Chunks whose every id is the invalid sentinel are skipped entirely
    (a cheap predicate guards the gather + reduction) — with the dedupe
    stage packing unique ids first, the loop does O(#unique) work however
    large the L·C probe budget is. With ``delta``, each chunk gathers from
    whichever segment owns each id (virtual concatenation, never built)."""
    n_main, d = data.shape
    cap = 0 if delta is None else delta.shape[0]
    n = n_main + cap
    b, P = ids.shape
    pc = -P % chunk
    ids_p = jnp.pad(ids.astype(jnp.int32), ((0, 0), (0, pc)), constant_values=n)
    n_chunks = ids_p.shape[1] // chunk
    q = queries.astype(jnp.float32)
    w = weights.astype(jnp.float32)
    data_f = data.astype(jnp.float32)
    delta_f = None if delta is None else delta.astype(data.dtype).astype(jnp.float32)

    def gather(cid):  # (b, chunk) ids -> (b, chunk, d) rows
        if delta_f is None:
            return data_f[jnp.minimum(cid, n - 1)]

        # dedupe packs ids ascending, so most chunks live entirely in one
        # segment — branch to a single gather there and pay the two-gather
        # select only on the (rare) boundary chunk. All branches produce
        # identical rows for every valid id (invalid ids clamp to the same
        # row and are masked to +inf downstream), so the specialization
        # cannot change results.
        def main_only(_):
            return data_f[jnp.minimum(cid, n_main - 1)]

        def delta_only(_):
            return delta_f[jnp.clip(cid - n_main, 0, cap - 1)]

        def mixed(_):
            return jnp.where((cid < n_main)[..., None], main_only(None), delta_only(None))

        in_main = cid < n_main
        return jax.lax.cond(
            jnp.all(in_main),
            main_only,
            lambda _: jax.lax.cond(jnp.any(in_main), mixed, delta_only, None),
            None,
        )

    def body(c, carry):
        cid = jax.lax.dynamic_slice_in_dim(ids_p, c * chunk, chunk, axis=1)  # (b, chunk)
        valid = cid < n

        def compute(carry):
            top_d, top_i = carry
            pts = gather(cid)  # (b, chunk, d)
            dists = jnp.sum(w[:, None, :] * jnp.abs(pts - q[:, None, :]), axis=-1)
            dists = jnp.where(valid, dists, jnp.inf)
            cand_d = jnp.concatenate([top_d, dists], axis=1)
            cand_i = jnp.concatenate([top_i, jnp.where(valid, cid, -1)], axis=1)
            neg, sel = jax.lax.top_k(-cand_d, top_d.shape[1])
            return -neg, jnp.take_along_axis(cand_i, sel, axis=1)

        return jax.lax.cond(jnp.any(valid), compute, lambda cr: cr, carry)

    kk = max(1, min(k, P))
    top_d = jnp.full((b, kk), jnp.inf, jnp.float32)
    top_i = jnp.full((b, kk), -1, jnp.int32)
    top_d, top_i = jax.lax.fori_loop(0, n_chunks, body, (top_d, top_i))
    if top_d.shape[1] < k:
        top_d = jnp.pad(top_d, ((0, 0), (0, k - top_d.shape[1])), constant_values=jnp.inf)
        top_i = jnp.pad(top_i, ((0, 0), (0, k - top_i.shape[1])), constant_values=-1)
    return top_d[:, :k], jnp.where(jnp.isfinite(top_d[:, :k]), top_i[:, :k], -1)
