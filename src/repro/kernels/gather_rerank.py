"""Fused ALSH probe tail: scalar-prefetch gather + exact re-rank + top-k.

The unfused tail (`index.data[ids]` → wl1_rerank → lax.top_k) materializes a
(b, L·C, d) candidate tensor in HBM and reads it straight back — for the
standard b=64, L·C=4096, d=128 probe that is two full passes over 128 MB the
query never needed. This kernel removes it: candidate ids are handed to
Pallas as **scalar-prefetch** arguments (`pltpu.PrefetchScalarGridSpec`), so
the BlockSpec index map — evaluated ahead of the grid step — points the
pipeline's DMA engine directly at the needed `(1, d-chunk)` row of the
(n, d) table in HBM. Each candidate's weighted |diff| partial sums accumulate
in a scalar scratch across d-chunks; the finished distance is folded into a
per-query VMEM top-k buffer by replace-max insertion:

  grid (query i, candidate j, d-chunk kd):
    data block  (1, BDR)  @ row  min(ids[i, j], n-1)   — the gather
    out blocks  (1, KP)   @ i                          — running top-k

Invalid candidates (padding, duplicates zapped by dedupe) carry the sentinel
id n: the index map clamps them to a readable row and the merge step drops
them. The buffer holds the KP (=128-aligned) smallest distances unsorted; the
wrapper sorts the (b, KP) result and slices (b, k) — exactly the oracle's
`ref.gather_rerank_topk` semantics ((+inf, -1) tails when fewer than k valid).

The CPU production path (`gather_rerank_topk_auto`) fuses in pure jnp and
picks its schedule by static footprint: a monolithic single-pass (one XLA
fusion region, no inter-stage materialization) while the (b, P, d) working
set is cache-resident, switching to `gather_rerank_topk_chunked` — a
fori_loop over candidate chunks (gather chunk → re-rank → top-k merge) that
keeps the live set at O(b·chunk·d) and skips all-sentinel chunks — once the
monolith would spill.

Quantized storage (`scales=` / non-f32 `data` on every entry point, see
repro.quant): the table payload may be bf16 or symmetric-int8 rows. Every
schedule gathers the ENCODED row and decodes in-register (widen to f32,
then `* scales` when the codec stored them) — the DMA stream stays
byte-bound at the compressed width and no f32 copy of the table is ever
materialized. The jnp schedules decode per gathered candidate chunk; the
Pallas path switches to `gather_rerank_topk_pallas_blocked`, which
additionally coalesces the gather: each grid step prefetches a BLOCK of
`CBLK` candidate rows as `CBLK` parallel scalar-prefetch streams (batch
DMA per candidate block instead of one row per step), accumulates their
partial sums side by side in SMEM, and folds all `CBLK` finished distances
into the top-k buffer in candidate order — bit-identical insertion order
to the per-row kernel, several row DMAs in flight instead of one.

Two-segment mode (`delta=` on every entry point): a mutable index re-ranks
against a sealed (n_main, d) main table PLUS an unsealed (cap, d) delta
table, with candidate ids addressing their virtual concatenation (id i >=
n_main is delta slot i - n_main). Rather than concatenating the tables per
query batch — an O((n_main + cap)·d) HBM copy the old two-segment tail
paid — every schedule gathers from whichever segment owns each id: the
Pallas kernel runs BOTH tables as scalar-prefetch gather streams (the
index maps clamp each id into its own segment; the kernel keeps the
partial sum of the owning segment), and the jnp schedules select per
candidate between two clamped row gathers. Bit-identical to the
concatenated-table result.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BDR = 128  # coordinates per d-chunk (gather DMA granularity)
KP_LANE = 128  # top-k buffer lane alignment
CBLK = 8  # candidate rows gathered per grid step by the blocked schedule


def _gather_rerank_kernel(ids_ref, row_ref, q_ref, w_ref, outd_ref, outi_ref, acc_ref, *, n: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    kd = pl.program_id(2)
    nd = pl.num_programs(2)

    @pl.when((j == 0) & (kd == 0))
    def _init_topk():
        outd_ref[...] = jnp.full_like(outd_ref, jnp.inf)
        outi_ref[...] = jnp.full_like(outi_ref, -1)

    partial = jnp.sum(w_ref[...] * jnp.abs(row_ref[...] - q_ref[...]))  # scalar

    @pl.when(kd == 0)
    def _acc_init():
        acc_ref[0, 0] = partial

    @pl.when(kd != 0)
    def _acc():
        acc_ref[0, 0] += partial

    @pl.when(kd == nd - 1)
    def _merge():
        cid = ids_ref[i, j]
        dist = acc_ref[0, 0]
        cur_d = outd_ref[...]  # (1, KP)
        cur_i = outi_ref[...]
        worst = jnp.max(cur_d)
        slot = jnp.argmax(cur_d)  # first-occurrence ⇒ fills +inf slots in order

        @pl.when((cid < n) & (dist < worst))
        def _insert():
            lane = jax.lax.broadcasted_iota(jnp.int32, cur_d.shape, 1)
            put = lane == slot
            outd_ref[...] = jnp.where(put, dist, cur_d)
            outi_ref[...] = jnp.where(put, cid, cur_i)


def _gather_rerank2_kernel(
    ids_ref, main_ref, delta_ref, q_ref, w_ref, outd_ref, outi_ref, acc_ref,
    *, n_main: int, n_tot: int,
):
    """Two-segment variant: the grid pipelines BOTH segment tables as
    scalar-prefetch gather streams (each index map clamps the candidate id
    into its own segment), and the accumulator keeps whichever partial sum
    belongs to the segment that owns the id — the merge step is unchanged."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    kd = pl.program_id(2)
    nd = pl.num_programs(2)

    @pl.when((j == 0) & (kd == 0))
    def _init_topk():
        outd_ref[...] = jnp.full_like(outd_ref, jnp.inf)
        outi_ref[...] = jnp.full_like(outi_ref, -1)

    cid = ids_ref[i, j]
    part_m = jnp.sum(w_ref[...] * jnp.abs(main_ref[...] - q_ref[...]))  # scalar
    part_d = jnp.sum(w_ref[...] * jnp.abs(delta_ref[...] - q_ref[...]))
    partial = jnp.where(cid < n_main, part_m, part_d)

    @pl.when(kd == 0)
    def _acc_init():
        acc_ref[0, 0] = partial

    @pl.when(kd != 0)
    def _acc():
        acc_ref[0, 0] += partial

    @pl.when(kd == nd - 1)
    def _merge():
        dist = acc_ref[0, 0]
        cur_d = outd_ref[...]  # (1, KP)
        cur_i = outi_ref[...]
        worst = jnp.max(cur_d)
        slot = jnp.argmax(cur_d)  # first-occurrence ⇒ fills +inf slots in order

        @pl.when((cid < n_tot) & (dist < worst))
        def _insert():
            lane = jax.lax.broadcasted_iota(jnp.int32, cur_d.shape, 1)
            put = lane == slot
            outd_ref[...] = jnp.where(put, dist, cur_d)
            outi_ref[...] = jnp.where(put, cid, cur_i)


def _make_blocked_kernel(cb: int, n_main: int, n_tot: int, two_seg: bool):
    """The block-coalesced kernel body: ``cb`` candidate rows per grid step.

    Ref layout (after the scalar-prefetch ids): ``cb`` main-row streams,
    [``cb`` delta-row streams,] scales, q, w | outd, outi | (1, cb) SMEM
    accumulator. The per-candidate math, accumulation order over d-chunks,
    and top-k insertion order (global candidate order jb·cb + c) are all
    IDENTICAL to the per-row kernels — same buffers, bit for bit — only the
    DMA schedule changes: cb gather streams are in flight per step instead
    of one."""

    def kernel(ids_ref, *refs):
        nrow = cb * (2 if two_seg else 1)
        rows = refs[:nrow]
        sc_ref, q_ref, w_ref, outd_ref, outi_ref, acc_ref = refs[nrow:]
        i = pl.program_id(0)
        jb = pl.program_id(1)
        kd = pl.program_id(2)
        nd = pl.num_programs(2)

        @pl.when((jb == 0) & (kd == 0))
        def _init_topk():
            outd_ref[...] = jnp.full_like(outd_ref, jnp.inf)
            outi_ref[...] = jnp.full_like(outi_ref, -1)

        sc = sc_ref[...]  # (1, BDR) decode scales (exact ones when unscaled)
        for c in range(cb):
            row = rows[c][...].astype(jnp.float32) * sc
            part = jnp.sum(w_ref[...] * jnp.abs(row - q_ref[...]))  # scalar
            if two_seg:
                drow = rows[cb + c][...].astype(jnp.float32) * sc
                dpart = jnp.sum(w_ref[...] * jnp.abs(drow - q_ref[...]))
                part = jnp.where(ids_ref[i, jb * cb + c] < n_main, part, dpart)

            @pl.when(kd == 0)
            def _acc_init(c=c, part=part):
                acc_ref[0, c] = part

            @pl.when(kd != 0)
            def _acc(c=c, part=part):
                acc_ref[0, c] += part

        @pl.when(kd == nd - 1)
        def _merge():
            for c in range(cb):
                cid = ids_ref[i, jb * cb + c]
                dist = acc_ref[0, c]
                cur_d = outd_ref[...]  # (1, KP)
                cur_i = outi_ref[...]
                worst = jnp.max(cur_d)
                slot = jnp.argmax(cur_d)  # first-occurrence ⇒ +inf slots fill in order
                lane = jax.lax.broadcasted_iota(jnp.int32, cur_d.shape, 1)
                put = (lane == slot) & (cid < n_tot) & (dist < worst)
                outd_ref[...] = jnp.where(put, dist, cur_d)
                outi_ref[...] = jnp.where(put, cid, cur_i)

    return kernel


@functools.partial(jax.jit, static_argnames=("k", "cb", "interpret"))
def gather_rerank_topk_pallas_blocked(
    data: jax.Array,
    ids: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
    *,
    delta: jax.Array | None = None,
    scales: jax.Array | None = None,
    cb: int = CBLK,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Block-coalesced Pallas schedule: same contract as
    ``gather_rerank_topk_pallas`` plus quantized-storage decode.

    The table payload keeps its STORED dtype end to end — the gather DMA
    moves encoded (bf16/int8) bytes and the kernel decodes in-register
    (widen + ``* scales``), so a quantized table is read at its compressed
    width. Each grid step gathers ``cb`` candidate rows as ``cb`` parallel
    scalar-prefetch streams (batch DMA per candidate block). With f32 data
    and no scales the result is bit-identical to the per-row kernel (the
    decode multiplies by exact 1.0 and the insertion order matches)."""
    n, d = data.shape
    b, P = ids.shape
    cap = 0 if delta is None else delta.shape[0]
    n_tot = n + cap
    kp = -min(k, P) % KP_LANE + min(k, P)
    pd = -d % BDR
    dp = d + pd
    data_p = jnp.pad(data, ((0, 0), (0, pd)))  # encoded dtype preserved
    q_p = jnp.pad(queries.astype(jnp.float32), ((0, 0), (0, pd)))
    w_p = jnp.pad(weights.astype(jnp.float32), ((0, 0), (0, pd)))
    sc = jnp.ones((d,), jnp.float32) if scales is None else scales.astype(jnp.float32)
    sc_p = jnp.pad(sc.reshape(1, d), ((0, 0), (0, pd)))
    pc = -P % cb
    ids_p = jnp.pad(ids.astype(jnp.int32), ((0, 0), (0, pc)), constant_values=n_tot)
    grid = (b, ids_p.shape[1] // cb, dp // BDR)

    def _row_map(c):
        return lambda i, jb, kd, ids_ref: (
            jnp.minimum(ids_ref[i, jb * cb + c], n - 1), kd,
        )

    row_specs = [pl.BlockSpec((1, BDR), _row_map(c)) for c in range(cb)]
    sc_spec = pl.BlockSpec((1, BDR), lambda i, jb, kd, ids_ref: (0, kd))
    qw_spec = pl.BlockSpec((1, BDR), lambda i, jb, kd, ids_ref: (i, kd))
    out_spec = pl.BlockSpec((1, kp), lambda i, jb, kd, ids_ref: (i, 0))
    if delta is None:
        tables = (data_p,) * cb
        kernel = _make_blocked_kernel(cb, n_main=n, n_tot=n, two_seg=False)
        in_specs = [*row_specs, sc_spec, qw_spec, qw_spec]
    else:

        def _delta_map(c):
            return lambda i, jb, kd, ids_ref: (
                jnp.clip(ids_ref[i, jb * cb + c] - n, 0, cap - 1), kd,
            )

        delta_p = jnp.pad(delta.astype(data.dtype), ((0, 0), (0, pd)))
        delta_specs = [pl.BlockSpec((1, BDR), _delta_map(c)) for c in range(cb)]
        tables = (data_p,) * cb + (delta_p,) * cb
        kernel = _make_blocked_kernel(cb, n_main=n, n_tot=n_tot, two_seg=True)
        in_specs = [*row_specs, *delta_specs, sc_spec, qw_spec, qw_spec]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=(out_spec, out_spec),
        scratch_shapes=[pltpu.SMEM((1, cb), jnp.float32)],
    )
    out_d, out_i = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((b, kp), jnp.float32),
            jax.ShapeDtypeStruct((b, kp), jnp.int32),
        ),
        interpret=interpret,
    )(ids_p, *tables, sc_p, q_p, w_p)
    from repro.kernels.ref import _topk_ascending

    return _topk_ascending(out_d, out_i, k)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def gather_rerank_topk_pallas(
    data: jax.Array,
    ids: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
    *,
    delta: jax.Array | None = None,
    scales: jax.Array | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """data (n, d), ids (b, P) int32 (>= n ⇒ invalid), queries/weights (b, d)
    -> ((b, k) ascending dists, (b, k) ids). With ``delta`` (cap, d), ids
    address the virtual [data; delta] concatenation (never materialized).

    Quantized storage (non-f32 ``data`` and/or ``scales``) routes to the
    block-coalesced schedule, which gathers the encoded rows and decodes
    in-register; the f32 path below is the pre-quantization program,
    untouched."""
    if data.dtype != jnp.float32 or scales is not None:
        return gather_rerank_topk_pallas_blocked(
            data, ids, queries, weights, k,
            delta=delta, scales=scales, interpret=interpret,
        )
    n, d = data.shape
    b, P = ids.shape
    kp = -min(k, P) % KP_LANE + min(k, P)
    pd = -d % BDR
    data_p = jnp.pad(data.astype(jnp.float32), ((0, 0), (0, pd)))
    q_p = jnp.pad(queries.astype(jnp.float32), ((0, 0), (0, pd)))
    w_p = jnp.pad(weights.astype(jnp.float32), ((0, 0), (0, pd)))
    dp = d + pd
    grid = (b, P, dp // BDR)
    row_spec = pl.BlockSpec(
        (1, BDR), lambda i, j, kd, ids_ref: (jnp.minimum(ids_ref[i, j], n - 1), kd)
    )
    qw_spec = pl.BlockSpec((1, BDR), lambda i, j, kd, ids_ref: (i, kd))
    out_spec = pl.BlockSpec((1, kp), lambda i, j, kd, ids_ref: (i, 0))
    if delta is None:
        in_specs = [row_spec, qw_spec, qw_spec]
        kernel = functools.partial(_gather_rerank_kernel, n=n)
        tables = (data_p,)
    else:
        cap = delta.shape[0]
        # round delta rows through the main table's dtype first — the same
        # cast every other schedule (and the old concat path) applies, so
        # mixed-dtype segments rerank identically across backends
        delta_p = jnp.pad(delta.astype(data.dtype).astype(jnp.float32), ((0, 0), (0, pd)))
        delta_spec = pl.BlockSpec(
            (1, BDR),
            lambda i, j, kd, ids_ref: (jnp.clip(ids_ref[i, j] - n, 0, cap - 1), kd),
        )
        in_specs = [row_spec, delta_spec, qw_spec, qw_spec]
        kernel = functools.partial(_gather_rerank2_kernel, n_main=n, n_tot=n + cap)
        tables = (data_p, delta_p)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=(out_spec, out_spec),
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.float32)],
    )
    out_d, out_i = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((b, kp), jnp.float32),
            jax.ShapeDtypeStruct((b, kp), jnp.int32),
        ),
        interpret=interpret,
    )(ids.astype(jnp.int32), *tables, q_p, w_p)
    # buffer is the kp smallest, unsorted — order + trim to k outside the kernel
    from repro.kernels.ref import _topk_ascending

    return _topk_ascending(out_d, out_i, k)


# Above this candidate-tensor footprint (b·P·d·4 bytes) the one-shot XLA
# fusion starts spilling LLC on CPU and the chunked streaming schedule wins
# (measured crossover between 16 MB and 32 MB on x86; see BENCH_kernels.json).
MONOLITH_BYTES = 24 * 1024 * 1024


@functools.partial(jax.jit, static_argnames=("k",))
def _gather_rerank_topk_monolith(
    data: jax.Array,
    ids: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
    delta: jax.Array | None = None,
    scales: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """One-shot fused tail: same math as the oracle but inside a single jit
    region, so XLA folds gather → re-rank → top-k into one pass with no
    inter-stage materialization. Best schedule while the candidate tensor
    stays cache-resident."""
    from repro.kernels import ref

    if delta is None:
        return ref.gather_rerank_topk(data, ids, queries, weights, k, scales=scales)
    return ref.gather_rerank_topk_segmented(
        data, delta, ids, queries, weights, k, scales=scales
    )


def gather_rerank_topk_auto(
    data: jax.Array,
    ids: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
    delta: jax.Array | None = None,
    scales: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """CPU production dispatch: pick the fused schedule by static footprint —
    monolithic single-pass when the (b, P, d) working set fits on-chip,
    chunked streaming (skip-capable) when it would spill. The two-segment
    monolith materializes both per-segment gathers plus their select (~3x
    the single-segment working set), so its budget is scaled to match.
    The footprint model stays at 4 bytes/value for quantized payloads too —
    both schedules decode the gathered chunk to f32, so the DECODED
    candidate tensor is what competes for cache."""
    b, P = ids.shape
    d = data.shape[1]
    working_set = b * P * d * 4 * (3 if delta is not None else 1)
    if working_set <= MONOLITH_BYTES:
        return _gather_rerank_topk_monolith(
            data, ids, queries, weights, k, delta=delta, scales=scales
        )
    return gather_rerank_topk_chunked(
        data, ids, queries, weights, k, delta=delta, scales=scales
    )


# The streamed early-exit tail merges (b, k + G·C) blocks per group — far
# smaller than a full-plan candidate tensor, but re-ranked once per
# while_loop iteration, so the chunked fori_loop's per-chunk bookkeeping is
# paid n_groups times over. The group entry therefore prefers the monolithic
# fusion up to a 2x wider footprint before falling back to chunking.
GROUP_MONOLITH_BYTES = 2 * MONOLITH_BYTES


def gather_rerank_topk_group(
    data: jax.Array,
    ids: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
    delta: jax.Array | None = None,
    scales: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Group-sized fused tail for the streamed early-exit loop: the same
    contract (and bit-identical selection — both schedules are tested
    equal) as :func:`gather_rerank_topk_auto`, with the monolith/chunked
    crossover moved to ``GROUP_MONOLITH_BYTES`` because the caller invokes
    it once per while_loop iteration on heap+group-sized blocks."""
    b, P = ids.shape
    d = data.shape[1]
    working_set = b * P * d * 4 * (3 if delta is not None else 1)
    if working_set <= GROUP_MONOLITH_BYTES:
        return _gather_rerank_topk_monolith(
            data, ids, queries, weights, k, delta=delta, scales=scales
        )
    return gather_rerank_topk_chunked(
        data, ids, queries, weights, k, delta=delta, scales=scales
    )


@functools.partial(jax.jit, static_argnames=("k", "chunk"))
def gather_rerank_topk_chunked(
    data: jax.Array,
    ids: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
    chunk: int = 256,
    delta: jax.Array | None = None,
    scales: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Pure-jnp fused tail (CPU production path): chunked gather → re-rank →
    streaming top-k merge. Never materializes the (b, P, d) tensor.

    Chunks whose every id is the invalid sentinel are skipped entirely
    (a cheap predicate guards the gather + reduction) — with the dedupe
    stage packing unique ids first, the loop does O(#unique) work however
    large the L·C probe budget is. With ``delta``, each chunk gathers from
    whichever segment owns each id (virtual concatenation, never built).

    Quantized payloads stay encoded at rest: the gather moves rows in the
    STORED dtype and each chunk is decoded (widen + ``* scales``) right
    before its re-rank, so only (b, chunk, d) f32 values ever exist. For
    f32 data the decode is an identity cast — bit-identical to gathering
    from a pre-cast table."""
    n_main, d = data.shape
    cap = 0 if delta is None else delta.shape[0]
    n = n_main + cap
    b, P = ids.shape
    pc = -P % chunk
    ids_p = jnp.pad(ids.astype(jnp.int32), ((0, 0), (0, pc)), constant_values=n)
    n_chunks = ids_p.shape[1] // chunk
    q = queries.astype(jnp.float32)
    w = weights.astype(jnp.float32)
    # delta rows round through the main table's dtype (same cast every other
    # schedule applies) so mixed-dtype segments rerank identically
    delta_e = None if delta is None else delta.astype(data.dtype)

    def decode(pts):  # (b, chunk, d) stored-dtype rows -> f32 rows
        pts = pts.astype(jnp.float32)
        if scales is not None:
            pts = pts * scales
        return pts

    def gather(cid):  # (b, chunk) ids -> (b, chunk, d) encoded rows
        if delta_e is None:
            return data[jnp.minimum(cid, n - 1)]

        # dedupe packs ids ascending, so most chunks live entirely in one
        # segment — branch to a single gather there and pay the two-gather
        # select only on the (rare) boundary chunk. All branches produce
        # identical rows for every valid id (invalid ids clamp to the same
        # row and are masked to +inf downstream), so the specialization
        # cannot change results.
        def main_only(_):
            return data[jnp.minimum(cid, n_main - 1)]

        def delta_only(_):
            return delta_e[jnp.clip(cid - n_main, 0, cap - 1)]

        def mixed(_):
            return jnp.where((cid < n_main)[..., None], main_only(None), delta_only(None))

        in_main = cid < n_main
        return jax.lax.cond(
            jnp.all(in_main),
            main_only,
            lambda _: jax.lax.cond(jnp.any(in_main), mixed, delta_only, None),
            None,
        )

    def body(c, carry):
        cid = jax.lax.dynamic_slice_in_dim(ids_p, c * chunk, chunk, axis=1)  # (b, chunk)
        valid = cid < n

        def compute(carry):
            top_d, top_i = carry
            pts = decode(gather(cid))  # (b, chunk, d)
            dists = jnp.sum(w[:, None, :] * jnp.abs(pts - q[:, None, :]), axis=-1)
            dists = jnp.where(valid, dists, jnp.inf)
            cand_d = jnp.concatenate([top_d, dists], axis=1)
            cand_i = jnp.concatenate([top_i, jnp.where(valid, cid, -1)], axis=1)
            neg, sel = jax.lax.top_k(-cand_d, top_d.shape[1])
            return -neg, jnp.take_along_axis(cand_i, sel, axis=1)

        return jax.lax.cond(jnp.any(valid), compute, lambda cr: cr, carry)

    kk = max(1, min(k, P))
    top_d = jnp.full((b, kk), jnp.inf, jnp.float32)
    top_i = jnp.full((b, kk), -1, jnp.int32)
    top_d, top_i = jax.lax.fori_loop(0, n_chunks, body, (top_d, top_i))
    if top_d.shape[1] < k:
        top_d = jnp.pad(top_d, ((0, 0), (0, k - top_d.shape[1])), constant_values=jnp.inf)
        top_i = jnp.pad(top_i, ((0, 0), (0, k - top_i.shape[1])), constant_values=-1)
    return top_d[:, :k], jnp.where(jnp.isfinite(top_d[:, :k]), top_i[:, :k], -1)
