"""jit'd dispatch wrappers for the Pallas kernels.

Production call sites go through these. Dispatch policy:

  * TPU backend          -> compiled Pallas kernels.
  * CPU/other backends   -> pure-jnp implementations: the ref.py oracles for
                            the elementwise kernels, and the *chunked
                            streaming* variants for the fused top-k paths
                            (same fusion, cache-sized working set); tests
                            separately exercise the Pallas bodies with
                            interpret=True to validate them on CPU.

Override with ``force="pallas" | "ref" | "interpret" | "chunked"`` for
benchmarking (``chunked`` only exists for the fused top-k ops).
"""

from __future__ import annotations

import jax

from repro.kernels import alsh_project as _proj
from repro.kernels import gather_rerank as _gr
from repro.kernels import ref as _ref
from repro.kernels import wl1_distance as _wl1
from repro.kernels import wl1_topk as _topk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def alsh_project(
    levels: jax.Array,
    folded: jax.Array,
    weights: jax.Array | None = None,
    force: str | None = None,
) -> jax.Array:
    """§4.2.3 hash projection: (n, d) levels × (H, d, M+1) tables -> (n, H)."""
    mode = force or ("pallas" if _on_tpu() else "ref")
    if mode == "pallas":
        return _proj.alsh_project_pallas(levels, folded, weights)
    if mode == "interpret":
        return _proj.alsh_project_pallas(levels, folded, weights, interpret=True)
    return _ref.alsh_project(levels, folded, weights)


def wl1_scan(
    data: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    force: str | None = None,
) -> jax.Array:
    """Exact brute-force scan: (n, d) × (b, d) -> (b, n) (materializing)."""
    mode = force or ("pallas" if _on_tpu() else "ref")
    if mode == "pallas":
        return _wl1.wl1_scan_pallas(data, queries, weights)
    if mode == "interpret":
        return _wl1.wl1_scan_pallas(data, queries, weights, interpret=True)
    return _ref.wl1_scan(data, queries, weights)


def wl1_rerank(
    pts: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    force: str | None = None,
) -> jax.Array:
    """Candidate re-rank: (b, C, d) × (b, d) -> (b, C)."""
    mode = force or ("pallas" if _on_tpu() else "ref")
    if mode == "pallas":
        return _wl1.wl1_rerank_pallas(pts, queries, weights)
    if mode == "interpret":
        return _wl1.wl1_rerank_pallas(pts, queries, weights, interpret=True)
    return _ref.wl1_rerank(pts, queries, weights)


def wl1_scan_topk(
    data: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
    force: str | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Streaming exact k-NN scan: (n, d) × (b, d) -> ((b, k), (b, k)) without
    the (b, n) distance matrix."""
    mode = force or ("pallas" if _on_tpu() else "chunked")
    if mode == "pallas":
        return _topk.wl1_scan_topk_pallas(data, queries, weights, k)
    if mode == "interpret":
        return _topk.wl1_scan_topk_pallas(data, queries, weights, k, interpret=True)
    if mode == "chunked":
        return _topk.wl1_scan_topk_chunked(data, queries, weights, k)
    return _ref.wl1_scan_topk(data, queries, weights, k)


def gather_rerank_topk(
    data: jax.Array,
    ids: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
    force: str | None = None,
    delta: jax.Array | None = None,
    scales: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused ALSH probe tail: (n, d) table + (b, P) candidate ids (>= n ⇒
    invalid) -> top-k ((b, k) dists, (b, k) ids) with no materialized
    (b, P, d) gather. CPU auto-dispatch picks monolithic vs chunked
    streaming by candidate-tensor footprint.

    With ``delta`` (cap, d), ids address the virtual [data; delta]
    concatenation (two-segment mutable index) — every backend gathers from
    whichever segment owns each id instead of building the concatenated
    table; results are bit-identical to the single-table call over
    ``concat([data, delta])``.

    ``data``/``delta`` may hold a quantized payload (bf16/int8 — see
    repro.quant): every backend gathers the ENCODED rows and decodes per
    candidate (widen to f32, then ``* scales`` when the codec stores them)
    before the re-rank. f32 payloads with no scales take the exact
    pre-quantization code paths."""
    mode = force or ("pallas" if _on_tpu() else "auto")
    if mode == "pallas":
        return _gr.gather_rerank_topk_pallas(
            data, ids, queries, weights, k, delta=delta, scales=scales
        )
    if mode == "interpret":
        return _gr.gather_rerank_topk_pallas(
            data, ids, queries, weights, k, delta=delta, scales=scales, interpret=True
        )
    if mode == "auto":
        return _gr.gather_rerank_topk_auto(
            data, ids, queries, weights, k, delta=delta, scales=scales
        )
    if mode == "chunked":
        return _gr.gather_rerank_topk_chunked(
            data, ids, queries, weights, k, delta=delta, scales=scales
        )
    if delta is None:
        return _ref.gather_rerank_topk(data, ids, queries, weights, k, scales=scales)
    return _ref.gather_rerank_topk_segmented(
        data, delta, ids, queries, weights, k, scales=scales
    )


def gather_rerank_topk_group(
    data: jax.Array,
    ids: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
    force: str | None = None,
    delta: jax.Array | None = None,
    scales: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused tail entry for GROUP-sized candidate blocks — the per-iteration
    merge of the streamed early-exit loop (repro.engine.stream). Identical
    id/sentinel/top-k contract to :func:`gather_rerank_topk`; on CPU the
    dispatch crossover is widened (see ``gather_rerank.GROUP_MONOLITH_BYTES``)
    so the small heap+group blocks stay in the monolithic fusion instead of
    paying the chunked schedule's bookkeeping once per while_loop step."""
    mode = force or ("pallas" if _on_tpu() else "group")
    if mode == "group":
        return _gr.gather_rerank_topk_group(
            data, ids, queries, weights, k, delta=delta, scales=scales
        )
    return gather_rerank_topk(
        data, ids, queries, weights, k, force=mode, delta=delta, scales=scales
    )
