"""jit'd dispatch wrappers for the Pallas kernels.

Production call sites go through these. Dispatch policy:

  * TPU backend          -> compiled Pallas kernels.
  * CPU/other backends   -> pure-jnp oracles from ref.py (fast XLA-CPU code);
                            tests separately exercise the Pallas bodies with
                            interpret=True to validate them on CPU.

Override with ``force="pallas" | "ref" | "interpret"`` for benchmarking.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import alsh_project as _proj
from repro.kernels import ref as _ref
from repro.kernels import wl1_distance as _wl1


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def alsh_project(
    levels: jax.Array,
    folded: jax.Array,
    weights: jax.Array | None = None,
    force: str | None = None,
) -> jax.Array:
    """§4.2.3 hash projection: (n, d) levels × (H, d, M+1) tables -> (n, H)."""
    mode = force or ("pallas" if _on_tpu() else "ref")
    if mode == "pallas":
        return _proj.alsh_project_pallas(levels, folded, weights)
    if mode == "interpret":
        return _proj.alsh_project_pallas(levels, folded, weights, interpret=True)
    return _ref.alsh_project(levels, folded, weights)


def wl1_scan(
    data: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    force: str | None = None,
) -> jax.Array:
    """Exact brute-force scan: (n, d) × (b, d) -> (b, n)."""
    mode = force or ("pallas" if _on_tpu() else "ref")
    if mode == "pallas":
        return _wl1.wl1_scan_pallas(data, queries, weights)
    if mode == "interpret":
        return _wl1.wl1_scan_pallas(data, queries, weights, interpret=True)
    return _ref.wl1_scan(data, queries, weights)


def wl1_rerank(
    pts: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    force: str | None = None,
) -> jax.Array:
    """Candidate re-rank: (b, C, d) × (b, d) -> (b, C)."""
    mode = force or ("pallas" if _on_tpu() else "ref")
    if mode == "pallas":
        return _wl1.wl1_rerank_pallas(pts, queries, weights)
    if mode == "interpret":
        return _wl1.wl1_rerank_pallas(pts, queries, weights, interpret=True)
    return _ref.wl1_rerank(pts, queries, weights)
