"""Pallas TPU kernels for exact generalized weighted Manhattan distance.

Two entry points:

  * ``wl1_scan``   — brute-force scan: data (n, d) × queries (b, d) -> (b, n).
    The linear-scan baseline the paper's sublinear scheme is measured against,
    and the building block of the distributed exact re-rank.
  * ``wl1_rerank`` — candidate re-rank: pts (b, C, d) × queries -> (b, C).
    The tail of every ALSH probe.

|o - q| has no MXU form on raw floats, so these are VPU kernels: blocked
elementwise |diff| * w with an in-register reduction over a d-chunk grid axis.
Data tiles are reused across the query-block dimension (the data tile is
loaded once per (query-block, d-chunk) step), giving O(bq) arithmetic
intensity per byte of data traffic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 8  # queries per block (scan)
BNV = 128  # data rows per block
BDV = 256  # coordinates per reduction step
BC = 128  # candidates per block (rerank)


def _scan_kernel(data_ref, q_ref, w_ref, out_ref):
    kd = pl.program_id(2)
    data = data_ref[...]  # (BNV, BDV)
    q = q_ref[...]  # (BQ, BDV)
    w = w_ref[...]  # (BQ, BDV)
    diff = jnp.abs(data[None, :, :] - q[:, None, :])  # (BQ, BNV, BDV)
    partial = jnp.sum(w[:, None, :] * diff, axis=-1)  # (BQ, BNV)

    @pl.when(kd == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(kd != 0)
    def _accum():
        out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("interpret",))
def wl1_scan_pallas(
    data: jax.Array, queries: jax.Array, weights: jax.Array, *, interpret: bool = False
) -> jax.Array:
    """data (n, d), queries (b, d), weights (b, d) -> (b, n) float32."""
    n, d = data.shape
    b, _ = queries.shape
    pn = -n % BNV
    pb = -b % BQ
    pd = -d % BDV
    # padded d-coords get w = 0 → contribute 0; padded rows/queries sliced away.
    data_p = jnp.pad(data.astype(jnp.float32), ((0, pn), (0, pd)))
    q_p = jnp.pad(queries.astype(jnp.float32), ((0, pb), (0, pd)))
    w_p = jnp.pad(weights.astype(jnp.float32), ((0, pb), (0, pd)))
    bp, dp = q_p.shape
    np_ = data_p.shape[0]
    grid = (bp // BQ, np_ // BNV, dp // BDV)
    out = pl.pallas_call(
        _scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BNV, BDV), lambda i, j, k: (j, k)),
            pl.BlockSpec((BQ, BDV), lambda i, j, k: (i, k)),
            pl.BlockSpec((BQ, BDV), lambda i, j, k: (i, k)),
        ],
        out_specs=pl.BlockSpec((BQ, BNV), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), jnp.float32),
        interpret=interpret,
    )(data_p, q_p, w_p)
    return out[:b, :n]


def _rerank_kernel(pts_ref, q_ref, w_ref, out_ref):
    kd = pl.program_id(2)
    pts = pts_ref[...]  # (1, BC, BDV)
    q = q_ref[...]  # (1, BDV)
    w = w_ref[...]  # (1, BDV)
    diff = jnp.abs(pts[0] - q[0][None, :])  # (BC, BDV)
    partial = jnp.sum(w[0][None, :] * diff, axis=-1)[None, :]  # (1, BC)

    @pl.when(kd == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(kd != 0)
    def _accum():
        out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("interpret",))
def wl1_rerank_pallas(
    pts: jax.Array, queries: jax.Array, weights: jax.Array, *, interpret: bool = False
) -> jax.Array:
    """pts (b, C, d), queries (b, d), weights (b, d) -> (b, C) float32."""
    b, C, d = pts.shape
    pc = -C % BC
    pd = -d % BDV
    pts_p = jnp.pad(pts.astype(jnp.float32), ((0, 0), (0, pc), (0, pd)))
    q_p = jnp.pad(queries.astype(jnp.float32), ((0, 0), (0, pd)))
    w_p = jnp.pad(weights.astype(jnp.float32), ((0, 0), (0, pd)))
    cp = C + pc
    dp = d + pd
    grid = (b, cp // BC, dp // BDV)
    out = pl.pallas_call(
        _rerank_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BC, BDV), lambda i, j, k: (i, j, k)),
            pl.BlockSpec((1, BDV), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, BDV), lambda i, j, k: (i, k)),
        ],
        out_specs=pl.BlockSpec((1, BC), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, cp), jnp.float32),
        interpret=interpret,
    )(pts_p, q_p, w_p)
    return out[:, :C]
