"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: tests sweep shapes/dtypes and
``assert_allclose`` the Pallas kernels (interpret=True on CPU) against these.
They are also the CPU production fallback used by ops.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def alsh_project(
    levels: jax.Array, folded: jax.Array, weights: jax.Array | None = None
) -> jax.Array:
    """§4.2.3 projection oracle: gather + (weighted) reduce.

    Args:
      levels: (n, d) int32 lattice points in {0..M}.
      folded: (H, d, M+1) float folded prefix tables b'.
      weights: optional (n, d) float query weights (None = data side).

    Returns:
      (n, H) float: proj[n, h] = sum_i w[n, i] * folded[h, i, levels[n, i]].
    """
    picked = jnp.take_along_axis(
        folded[None],  # (1, H, d, M+1)
        levels[:, None, :, None].astype(jnp.int32),  # (n, 1, d, 1)
        axis=3,
    )[..., 0]  # (n, H, d)
    if weights is not None:
        picked = picked * weights[:, None, :].astype(picked.dtype)
    return jnp.sum(picked, axis=-1)


def wl1_scan(data: jax.Array, queries: jax.Array, weights: jax.Array) -> jax.Array:
    """Brute-force weighted-Manhattan scan oracle.

    data (n, d), queries (b, d), weights (b, d) -> (b, n).
    """
    return jnp.sum(
        weights[:, None, :] * jnp.abs(data[None, :, :] - queries[:, None, :]), axis=-1
    )


def wl1_rerank(pts: jax.Array, queries: jax.Array, weights: jax.Array) -> jax.Array:
    """Candidate re-rank oracle.

    pts (b, C, d), queries (b, d), weights (b, d) -> (b, C).
    """
    return jnp.sum(
        weights[:, None, :] * jnp.abs(pts - queries[:, None, :]), axis=-1
    )


def _topk_ascending(dists: jax.Array, ids: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """k smallest of (b, m) dists with aligned ids; (+inf, -1) padded past m."""
    b, m = dists.shape
    if m < k:
        dists = jnp.pad(dists, ((0, 0), (0, k - m)), constant_values=jnp.inf)
        ids = jnp.pad(ids, ((0, 0), (0, k - m)), constant_values=-1)
    neg, sel = jax.lax.top_k(-dists, k)
    out_d = -neg
    out_i = jnp.take_along_axis(ids, sel, axis=1)
    return out_d, jnp.where(jnp.isfinite(out_d), out_i, -1)


def wl1_scan_topk(
    data: jax.Array, queries: jax.Array, weights: jax.Array, k: int
) -> tuple[jax.Array, jax.Array]:
    """Exact k-NN oracle: full (b, n) scan + top-k (the materializing baseline).

    data (n, d), queries (b, d), weights (b, d)
    -> ((b, k) ascending dists, (b, k) ids; (+inf, -1) where fewer than k rows).
    """
    n = data.shape[0]
    b = queries.shape[0]
    dists = wl1_scan(data, queries, weights)
    ids = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (b, n))
    return _topk_ascending(dists, ids, k)


def _decode_rows(pts: jax.Array, scales: jax.Array | None) -> jax.Array:
    """Quantized-storage row decode of a GATHERED candidate tensor: widen to
    f32, then apply the per-dimension scales when the codec stored them
    (symmetric int8). f32 rows pass through untouched — the default-storage
    oracle math is bit-identical to the pre-quantization code."""
    if pts.dtype != jnp.float32:
        pts = pts.astype(jnp.float32)
    if scales is not None:
        pts = pts * scales
    return pts


def gather_rerank_topk(
    data: jax.Array,
    ids: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
    scales: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused candidate-tail oracle: gather + exact d_w^l1 re-rank + top-k.

    This is the (deliberately) materializing 3-step reference the fused
    kernels are validated against: it builds the full (b, P, d) candidate
    tensor the production path exists to avoid.

    data (n, d); ids (b, P) int32 candidate ids, entries >= n are invalid
    sentinels (padding / duplicates marked by dedupe); queries/weights (b, d)
    -> ((b, k) ascending dists, (b, k) ids; (+inf, -1) where invalid).

    ``data`` may be a quantized payload (bf16/int8 — see repro.quant):
    gathered rows are decoded per candidate (widen, then ``* scales`` when
    given) before the f32 re-rank; the stored table is never decoded whole.
    """
    n = data.shape[0]
    valid = ids < n
    pts = _decode_rows(data[jnp.minimum(ids, n - 1)], scales)  # (b, P, d)
    dists = wl1_rerank(pts, queries, weights)
    dists = jnp.where(valid, dists, jnp.inf)
    return _topk_ascending(dists, jnp.where(valid, ids, -1).astype(jnp.int32), k)


def gather_rerank_topk_segmented(
    data: jax.Array,
    delta: jax.Array,
    ids: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
    scales: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Two-segment candidate-tail oracle: the virtual concatenation of
    ``data`` (n_main, d) and ``delta`` (cap, d) addressed by global ids —
    id i < n_main is a main row, i in [n_main, n_main + cap) is delta slot
    i - n_main, i >= n_main + cap is invalid. Bit-identical to
    ``gather_rerank_topk(concat([data, delta]), ...)`` without ever
    building the (n_main + cap, d) table. ``scales`` decodes quantized
    payloads per gathered row (delta rows are encoded with the sealed
    segment's scales, so one scale vector covers both segments)."""
    n_main = data.shape[0]
    cap = delta.shape[0]
    n = n_main + cap
    valid = ids < n
    delta = delta.astype(data.dtype)
    pts_m = data[jnp.minimum(ids, n_main - 1)]  # (b, P, d)
    pts_d = delta[jnp.clip(ids - n_main, 0, cap - 1)]
    pts = _decode_rows(jnp.where((ids < n_main)[..., None], pts_m, pts_d), scales)
    dists = wl1_rerank(pts, queries, weights)
    dists = jnp.where(valid, dists, jnp.inf)
    return _topk_ascending(dists, jnp.where(valid, ids, -1).astype(jnp.int32), k)
