"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: tests sweep shapes/dtypes and
``assert_allclose`` the Pallas kernels (interpret=True on CPU) against these.
They are also the CPU production fallback used by ops.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def alsh_project(
    levels: jax.Array, folded: jax.Array, weights: jax.Array | None = None
) -> jax.Array:
    """§4.2.3 projection oracle: gather + (weighted) reduce.

    Args:
      levels: (n, d) int32 lattice points in {0..M}.
      folded: (H, d, M+1) float folded prefix tables b'.
      weights: optional (n, d) float query weights (None = data side).

    Returns:
      (n, H) float: proj[n, h] = sum_i w[n, i] * folded[h, i, levels[n, i]].
    """
    picked = jnp.take_along_axis(
        folded[None],  # (1, H, d, M+1)
        levels[:, None, :, None].astype(jnp.int32),  # (n, 1, d, 1)
        axis=3,
    )[..., 0]  # (n, H, d)
    if weights is not None:
        picked = picked * weights[:, None, :].astype(picked.dtype)
    return jnp.sum(picked, axis=-1)


def wl1_scan(data: jax.Array, queries: jax.Array, weights: jax.Array) -> jax.Array:
    """Brute-force weighted-Manhattan scan oracle.

    data (n, d), queries (b, d), weights (b, d) -> (b, n).
    """
    return jnp.sum(
        weights[:, None, :] * jnp.abs(data[None, :, :] - queries[:, None, :]), axis=-1
    )


def wl1_rerank(pts: jax.Array, queries: jax.Array, weights: jax.Array) -> jax.Array:
    """Candidate re-rank oracle.

    pts (b, C, d), queries (b, d), weights (b, d) -> (b, C).
    """
    return jnp.sum(
        weights[:, None, :] * jnp.abs(pts - queries[:, None, :]), axis=-1
    )
