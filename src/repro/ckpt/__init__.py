from repro.ckpt.checkpoint import (
    AsyncCheckpointer,
    CorruptCheckpointError,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = [
    "AsyncCheckpointer",
    "CorruptCheckpointError",
    "latest_step",
    "restore_checkpoint",
    "save_checkpoint",
]
