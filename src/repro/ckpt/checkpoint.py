"""Sharded checkpointing: msgpack + zstd, atomic commit, async writer.

Layout (one directory per step):

    <dir>/step_000123/shard_<k>.msgpack.zst   — leaf buffers owned by host k
                                                (.msgpack.zlib when written
                                                by the zlib fallback)
    <dir>/step_000123/COMMIT                  — written LAST (atomic rename)

Restart protocol: readers only consider step dirs containing COMMIT, so a
crash mid-write can never be restored from (the fault-tolerance tests kill
training mid-step and restart from the last committed step). On multi-host
deployments each host writes the shards it owns (``shard_id``/``addressable``
path below); this container exercises the single-host path with identical
on-disk format.

Durability over raw speed: zstd level 3 (fast, stdlib zlib fallback when
zstandard is unavailable — frames are distinguished by magic on restore) +
contiguous buffers; the
AsyncCheckpointer overlaps serialization/IO with the next training steps and
is awaited before the step that would overwrite its data (double-buffering).
"""

from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard as zstd
except ModuleNotFoundError:  # offline containers: fall back to stdlib zlib
    zstd = None
import zlib

class CorruptCheckpointError(ValueError):
    """A committed checkpoint's bytes do not decode/verify — truncated or
    bit-flipped payload (decompress/unpack failure, per-leaf CRC mismatch).
    Restores raise this instead of handing back garbage arrays."""


_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"
# extension says what the WRITER produced (don't put zlib bytes in a .zst
# file); the reader accepts either and double-checks by frame magic.
_SHARD_EXTS = (".msgpack.zst", ".msgpack.zlib")
_WRITE_EXT = _SHARD_EXTS[0] if zstd is not None else _SHARD_EXTS[1]


def _compress(raw: bytes) -> bytes:
    if zstd is not None:
        return zstd.ZstdCompressor(level=3).compress(raw)
    return zlib.compress(raw, 3)


def _decompress(blob: bytes) -> bytes:
    # dispatch on the frame magic so either writer's files restore anywhere
    if blob[:4] == _ZSTD_MAGIC:
        if zstd is None:
            raise ModuleNotFoundError(
                "checkpoint was written with zstandard, which is not installed"
            )
        return zstd.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out)


def _flatten(tree) -> dict[str, np.ndarray]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        out[_path_str(path)] = arr
    return out


def save_checkpoint(directory: str, step: int, tree: Any, shard_id: int = 0) -> str:
    """Serialize + atomically commit one step. Returns the step dir."""
    step_dir = os.path.join(directory, f"step_{step:09d}")
    tmp_dir = step_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)

    flat = _flatten(tree)
    payload = {}
    for k, v in flat.items():
        data = v.tobytes()
        payload[k] = {
            "dtype": str(v.dtype),
            "shape": list(v.shape),
            "data": data,
            # per-leaf integrity: a bit-flip that survives decompression
            # (or slips past the zlib fallback's weak framing) is caught at
            # restore instead of loading as silently-garbage weights
            "crc": zlib.crc32(data),
        }
    raw = msgpack.packb(payload, use_bin_type=True)
    comp = _compress(raw)
    fname = os.path.join(tmp_dir, f"shard_{shard_id}{_WRITE_EXT}")
    with open(fname, "wb") as f:
        f.write(comp)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.replace(tmp_dir, step_dir)
    commit = os.path.join(step_dir, "COMMIT")
    with open(commit, "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    return step_dir


def latest_step(directory: str) -> Optional[int]:
    """Largest committed step in the directory (None if nothing committed)."""
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "COMMIT")):
            s = int(m.group(1))
            best = s if best is None or s > best else best
    return best


def restore_checkpoint(directory: str, step: int, template: Any, shard_id: int = 0) -> Any:
    """Rebuild the pytree (structure from ``template``, data from disk)."""
    step_dir = os.path.join(directory, f"step_{step:09d}")
    for ext in _SHARD_EXTS:
        fname = os.path.join(step_dir, f"shard_{shard_id}{ext}")
        if os.path.exists(fname):
            break
    else:
        raise FileNotFoundError(f"no shard_{shard_id} file in {step_dir}")
    with open(fname, "rb") as f:
        blob = f.read()
    try:
        raw = _decompress(blob)
        payload = msgpack.unpackb(raw, raw=False)
    except ModuleNotFoundError:
        raise  # zstd-written file without zstandard installed: actionable as-is
    except Exception as e:
        raise CorruptCheckpointError(
            f"checkpoint shard {fname} is corrupt (truncated or bit-flipped "
            f"payload): {type(e).__name__}: {e}"
        ) from e
    if not isinstance(payload, dict):
        raise CorruptCheckpointError(
            f"checkpoint shard {fname} decoded to {type(payload).__name__}, "
            f"not a leaf mapping — corrupt payload"
        )

    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in leaves:
        key = _path_str(path)
        if key not in payload:
            raise KeyError(f"checkpoint missing leaf {key}")
        rec = payload[key]
        if "crc" in rec and zlib.crc32(rec["data"]) != rec["crc"]:
            raise CorruptCheckpointError(
                f"checkpoint shard {fname} leaf {key!r} fails its CRC — "
                f"bytes were corrupted after commit; restore from another step"
            )
        try:
            arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"])).reshape(
                rec["shape"]
            )
        except (ValueError, TypeError) as e:
            raise CorruptCheckpointError(
                f"checkpoint shard {fname} leaf {key!r} does not match its "
                f"recorded dtype/shape ({e}) — corrupt payload"
            ) from e
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, [l for l in out])


class AsyncCheckpointer:
    """Overlap checkpoint IO with training (one in-flight save).

    Use as a context manager: ``__exit__`` flushes the in-flight save even
    when an exception unwinds the training loop, so a restart's
    ``latest_step`` read can never race the writer thread (the failure-
    injection drills raise ``SimulatedFailure`` mid-loop — without the
    flush, the last commit is nondeterministically visible). When the body
    is already unwinding, a save error is swallowed (the restart recovers
    from the previous commit, which is exactly the crash contract); on the
    clean path it propagates.
    """

    def __init__(self, directory: str):
        self.directory = directory
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def __enter__(self) -> "AsyncCheckpointer":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        try:
            self.wait()
        except BaseException:
            if exc_type is None:
                raise
            # already unwinding (e.g. an injected failure): don't mask the
            # primary error — a failed async save just means the restart
            # resumes from the previous commit
        return False

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        # device_get NOW (cheap on CPU, bounded copy on TPU) so training can
        # donate/overwrite the live buffers while the thread writes.
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def run():
            try:
                save_checkpoint(self.directory, step, host_tree)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
