"""End-to-end training driver: train a ~100M-param qwen3-family model on the
synthetic LM stream with the full production stack (AdamW, remat, microbatch
accumulation, async checkpointing, restart-safe data).

    PYTHONPATH=src python examples/train_small.py             # ~20M smoke (fast)
    PYTHONPATH=src python examples/train_small.py --full      # ~100M, few hundred steps

The loss should fall well below the unigram entropy of the stream within the
first hundred steps (the stream has learnable structure; see data/pipeline).
"""

import argparse
import dataclasses
import time

import jax

from repro.configs import ModelConfig, TrainConfig, get_bundle
from repro.configs.base import ArchBundle
from repro.data.pipeline import DataConfig
from repro.runtime.fault import train_loop


def small_qwen(full: bool) -> ModelConfig:
    if full:  # ~100M-param backbone (plus embeddings)
        return dataclasses.replace(
            get_bundle("qwen3-8b").model,
            n_layers=12, n_units=12, d_model=768, n_heads=12, n_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=8192, param_dtype="float32",
            compute_dtype="float32", remat=False,
        )
    return dataclasses.replace(
        get_bundle("qwen3-8b").model,
        n_layers=4, n_units=4, d_model=384, n_heads=6, n_kv_heads=2,
        head_dim=64, d_ff=1024, vocab_size=4096, param_dtype="float32",
        compute_dtype="float32", remat=False,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    args = ap.parse_args()

    mcfg = small_qwen(args.full)
    steps = args.steps or (300 if args.full else 60)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=20, total_steps=steps,
                       microbatch=1)
    bundle = ArchBundle(arch_id="train-small", model=mcfg, train=tcfg)
    dcfg = DataConfig(seq_len=256, global_batch=8)

    n_params = sum(
        x.size for x in jax.tree.leaves(
            jax.eval_shape(lambda: __import__("repro.models", fromlist=["models"])
                           .init_params(jax.random.PRNGKey(0), mcfg))
        )
    )
    print(f"[train_small] {n_params/1e6:.1f}M params, {steps} steps, "
          f"seq 256 x batch 8")

    t0 = time.time()
    losses = []

    def log(step, m):
        losses.append(m["loss"])
        if step % 10 == 0 or step == 1:
            print(f"  step {step:4d}  loss {m['loss']:.4f}  "
                  f"({(time.time()-t0)/step:.2f}s/step)")

    train_loop(bundle, dcfg, steps, args.ckpt_dir, ckpt_every=50,
               async_ckpt=True, on_metrics=log)
    first = sum(losses[:10]) / 10
    last = sum(losses[-10:]) / 10
    print(f"[train_small] loss {first:.3f} -> {last:.3f} "
          f"({'LEARNING' if last < first - 0.3 else 'check config'})")


if __name__ == "__main__":
    main()
