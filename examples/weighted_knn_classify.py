"""Feature-weighted kNN classification — the paper's motivating application
([3, 19]: per-query feature weighting for kNN classifiers).

    PYTHONPATH=src python examples/weighted_knn_classify.py

Synthetic task: 8-class Gaussian blobs in 24-D where only a per-class-known
subset of features is informative; the rest are noise. A weighted-Manhattan
kNN with weights = estimated feature importance (signal-to-noise per
dimension) classifies far better than unweighted kNN — and ALSH answers the
weighted queries sublinearly with matching accuracy.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import BoundedSpace, Index, IndexConfig, QuerySpec
from repro.distance import brute_force_nn


def make_blobs(key, n, d, n_classes, informative):
    kc, kx, kn = jax.random.split(key, 3)
    centers = jax.random.uniform(kc, (n_classes, d), minval=0.2, maxval=0.8)
    labels = jax.random.randint(kx, (n,), 0, n_classes)
    x = centers[labels]
    noise = jax.random.normal(kn, (n, d))
    scale = jnp.where(jnp.arange(d) < informative, 0.03, 0.35)  # noisy tail dims
    return jnp.clip(x + noise * scale[None, :], 0.0, 1.0), labels


def knn_accuracy(ids, train_labels, true_labels, k):
    votes = np.asarray(train_labels)[np.asarray(ids)]
    pred = np.array([np.bincount(v[v >= 0] if (v >= 0).any() else [0]).argmax()
                     for v in votes])
    return float(np.mean(pred == np.asarray(true_labels)))


def main():
    n, d, M, k, n_classes, informative = 30_000, 24, 32, 15, 8, 8
    key = jax.random.PRNGKey(7)
    X, y = make_blobs(jax.random.fold_in(key, 0), n, d, n_classes, informative)
    Q, yq = make_blobs(jax.random.fold_in(key, 1), 256, d, n_classes, informative)

    # per-dimension importance weights (signal-to-noise estimate)
    within_var = jnp.stack([jnp.var(X[y == c], axis=0) for c in range(n_classes)]).mean(0)
    total_var = jnp.var(X, axis=0)
    wvec = jnp.clip((total_var / (within_var + 1e-6)) - 1.0, 0.05, 50.0)
    W = jnp.broadcast_to(wvec, Q.shape)
    ones = jnp.ones_like(Q)

    print(f"== {n} train / {len(Q)} test, {d}-D, {informative} informative dims")

    _, ids_unw = brute_force_nn(X, Q, ones, k=k)
    acc_unw = knn_accuracy(ids_unw, y, yq, k)
    _, ids_w = brute_force_nn(X, Q, W, k=k)
    acc_w = knn_accuracy(ids_w, y, yq, k)
    print(f"== exact kNN accuracy: unweighted {acc_unw:.3f}  ->  weighted {acc_w:.3f}")

    cfg = IndexConfig(d=d, M=M, K=12, L=32, family="theta",
                      max_candidates=256, space=BoundedSpace(0.0, 1.0, float(M)))
    index = Index.build(jax.random.fold_in(key, 2), X, cfg)
    t0 = time.time()
    res = index.query(Q, W, QuerySpec(k=k))
    jax.block_until_ready(res.dists)
    acc_alsh = knn_accuracy(res.ids, y, yq, k)
    cand = float(jnp.mean(res.n_candidates))
    print(f"== ALSH weighted kNN: accuracy {acc_alsh:.3f} in {time.time()-t0:.2f}s, "
          f"examining {cand/n:.1%} of the database per query")
    print("== (weights ride with the query -- no reindexing when importance changes)")


if __name__ == "__main__":
    main()
