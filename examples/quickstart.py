"""Quickstart: sublinear NNS over generalized weighted Manhattan distance.

    PYTHONPATH=src python examples/quickstart.py

Builds a (d_w^l1, theta)-ALSH index over 50k points, runs weighted queries
(weights arrive WITH the query — the paper's setting), compares against the
exact linear scan, and prints the theory numbers (rho < 1 ⇒ sublinear).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BoundedSpace,
    IndexConfig,
    build_index,
    plan_index,
    query_index,
    rho,
)
from repro.distance import brute_force_nn


def _clustered(key, n, d, n_clusters=64):
    """Clustered data (realistic embedding-like geometry)."""
    kc, ka, kn = jax.random.split(key, 3)
    centers = jax.random.uniform(kc, (n_clusters, d), minval=0.15, maxval=0.85)
    assign = jax.random.randint(ka, (n,), 0, n_clusters)
    return jnp.clip(centers[assign] + 0.06 * jax.random.normal(kn, (n, d)), 0.0, 1.0)


def main():
    n, d, M, k = 50_000, 16, 32, 10
    key = jax.random.PRNGKey(0)
    space = BoundedSpace(0.0, 1.0, float(M))

    print(f"== dataset: n={n} d={d}, lattice M={M}")
    data = jax.random.uniform(jax.random.fold_in(key, 0), (n, d))

    # --- theory: the paper's complexity claim -------------------------------
    plan = plan_index(n=n, R1=0.05 * d, R2=0.4 * d, M=M, d=d, family="theta")
    print(f"== theory: P1={plan.P1:.3f} P2={plan.P2:.3f} rho={plan.rho:.3f} "
          f"(query time O(n^{plan.rho:.2f}) < O(n)) -> K={plan.K} L={plan.L}")

    cfg = IndexConfig(d=d, M=M, K=10, L=32, family="theta",
                      max_candidates=512, space=space)
    t0 = time.time()
    idx = build_index(jax.random.fold_in(key, 1), data, cfg)
    jax.block_until_ready(idx.sorted_keys)
    print(f"== built {cfg.L} tables x {cfg.K} hashes in {time.time()-t0:.2f}s "
          f"(O(d) per hash via the paper's §4.2.3 prefix trick)")

    # --- weighted queries ----------------------------------------------------
    b = 64
    q = jax.random.uniform(jax.random.fold_in(key, 2), (b, d))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (b, d))) + 0.2

    t0 = time.time()
    res = query_index(idx, q, w, cfg, k=k)
    jax.block_until_ready(res.dists)
    t_alsh = time.time() - t0

    t0 = time.time()
    bf_d, bf_i = brute_force_nn(data, q, w, k=k)
    jax.block_until_ready(bf_d)
    t_bf = time.time() - t0

    recall = np.mean([
        len(set(np.asarray(res.ids[i])) & set(np.asarray(bf_i[i]))) / k for i in range(b)
    ])
    cand = float(jnp.mean(res.n_candidates))
    print(f"== ALSH:  {t_alsh*1e3:7.1f} ms for {b} queries  "
          f"(examined {cand:.0f}/{n} = {cand/n:.1%} candidates/query)")
    print(f"== exact: {t_bf*1e3:7.1f} ms for {b} queries  (100% scanned)")
    print(f"== recall@{k} = {recall:.2f}")
    print(f"== negative weights are supported (each w_i may be <0, paper §1):")
    w_neg = jax.random.normal(jax.random.fold_in(key, 4), (b, d))
    res_neg = query_index(idx, q, w_neg, cfg, k=k)
    bfn_d, bfn_i = brute_force_nn(data, q, w_neg, k=k)
    rec_neg = np.mean([
        len(set(np.asarray(res_neg.ids[i])) & set(np.asarray(bfn_i[i]))) / k
        for i in range(b)
    ])
    print(f"   recall@{k} with mixed-sign weights: {rec_neg:.2f} "
          f"(harder geometry: near = most-negative distance)")


if __name__ == "__main__":
    main()
