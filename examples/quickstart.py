"""Quickstart: sublinear NNS over generalized weighted Manhattan distance,
through the ``repro.api`` facade — QUALITY-FIRST.

    PYTHONPATH=src python examples/quickstart.py [--n 50000]

States a recall target (``QualitySpec``) and lets the planner derive both
the index geometry (family/K/L/W/window — Theorems 4/5 inverted on a data
sample) and the execution policy (probe vs multiprobe, calibrated on-data).
Then shows the mechanism path (``IndexConfig`` + ``QuerySpec`` knobs, the
paper's raw surface), proves the two meet bit-identically, round-trips the
planned index through self-describing save/load, and prints per-query
diagnostics from ``Index.explain``.
"""

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Index, QualitySpec, QuerySpec
from repro.distance import recall_at_k


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    args = ap.parse_args()

    n, d, k = args.n, 16, 10
    key = jax.random.PRNGKey(0)

    print(f"== dataset: n={n} d={d}")
    data = jax.random.uniform(jax.random.fold_in(key, 0), (n, d))

    # --- say WHAT you need; the planner derives the HOW ---------------------
    quality = QualitySpec(k=k, recall_target=0.9, fail_prob=0.1)
    t0 = time.time()
    index = Index.build(jax.random.fold_in(key, 1), data, quality)
    jax.block_until_ready(index.state.sorted_keys)
    cfg = index.config
    print(f"== planned build in {time.time()-t0:.2f}s: family={cfg.family!r} "
          f"K={cfg.K} L={cfg.L} W={cfg.W:.3g} window={cfg.max_candidates} "
          f"(Thm 4/5 inverted on a {quality.calibration_queries}-point sample)")

    # --- weighted queries: weights arrive WITH the query (the paper's w) ----
    b = 64
    q = jax.random.uniform(jax.random.fold_in(key, 2), (b, d))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (b, d))) + 0.2

    t0 = time.time()
    plan = index.plan(quality)  # one calibration pass, memoized on the index
    print(f"== planned query in {time.time()-t0:.2f}s: mode={plan.mode!r} "
          f"n_probes={plan.n_probes} window={plan.max_candidates} "
          f"(calibrated recall {plan.predicted_recall:.2f}, "
          f"Thm 1 success bound {plan.predicted_success:.3f})")

    t0 = time.time()
    res = index.query(q, w, quality)  # resolves through the memoized plan
    jax.block_until_ready(res.dists)
    t_alsh = time.time() - t0

    t0 = time.time()
    ref = index.query(q, w, QuerySpec(k=k, mode="exact"))
    jax.block_until_ready(ref.dists)
    t_bf = time.time() - t0

    cand = float(jnp.mean(res.n_candidates))
    print(f"== ALSH:  {t_alsh*1e3:7.1f} ms for {b} queries  "
          f"(examined {cand:.0f}/{n} = {cand/n:.1%} candidates/query)")
    print(f"== exact: {t_bf*1e3:7.1f} ms for {b} queries  (100% scanned)")
    print(f"== measured recall@{k} = {recall_at_k(res.ids, ref.ids, k):.2f} "
          f"(target {quality.recall_target})")

    # --- the quality path IS the mechanism path — bit-identical -------------
    res_planned = index.query(q, w, plan)
    assert np.array_equal(np.asarray(res.ids), np.asarray(res_planned.ids))
    assert np.array_equal(np.asarray(res.dists), np.asarray(res_planned.dists))
    print("== query(QualitySpec) == query(resolved PlannedSpec), bit-identical")

    # explicit knobs still exist and still work (the paper's raw surface)
    res_knobs = index.query(q, w, plan.to_query_spec())
    print(f"== legacy knob path: QuerySpec{(plan.to_query_spec().mode, plan.k)} "
          f"recall@{k} = {recall_at_k(res_knobs.ids, ref.ids, k):.2f}")

    # --- per-query diagnostics ----------------------------------------------
    report = index.explain(q, w, quality)
    print(f"== explain: mean predicted success "
          f"{float(report.predicted_success.mean()):.3f}, "
          f"{int((report.truncated_tables > 0).sum())}/{b} queries hit window "
          f"truncation, {int((report.n_invalid > 0).sum())}/{b} returned "
          f"sentinel slots")

    # --- self-describing persistence (plans travel too) ---------------------
    with tempfile.TemporaryDirectory() as ckdir:
        index.save(ckdir)
        restored = Index.load(ckdir)  # directory alone — config + plans travel
        assert restored.plans == index.plans
        r2 = restored.query(q, w, quality)  # memo hit, no re-calibration
        assert np.array_equal(np.asarray(r2.ids), np.asarray(res.ids))
        print(f"== save/load round-trip: restored index (n={restored.n}, "
              f"family={restored.config.family!r}, {len(restored.plans)} "
              f"memoized plan) answers bit-identically")

    # --- negative weights (paper abstract: each w_i may be < 0) -------------
    w_neg = jax.random.normal(jax.random.fold_in(key, 4), (b, d))
    res_neg = index.query(q, w_neg, plan)
    ref_neg = index.query(q, w_neg, QuerySpec(k=k, mode="exact"))
    print(f"== mixed-sign weights: recall@{k} = "
          f"{recall_at_k(res_neg.ids, ref_neg.ids, k):.2f} "
          f"(harder geometry: near = most-negative distance)")


if __name__ == "__main__":
    main()
