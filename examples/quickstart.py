"""Quickstart: sublinear NNS over generalized weighted Manhattan distance,
through the ``repro.api`` facade.

    PYTHONPATH=src python examples/quickstart.py [--n 50000]

Builds a (d_w^l1, theta)-ALSH index over n points, runs weighted queries
(weights arrive WITH the query — the paper's setting) under three QuerySpec
policies (exact | single-probe | multiprobe), round-trips the index through
self-describing save/load, and prints the theory numbers (rho < 1 ⇒
sublinear).
"""

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import BoundedSpace, Index, IndexConfig, QuerySpec
from repro.core import plan_index
from repro.distance import recall_at_k


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    args = ap.parse_args()

    n, d, M, k = args.n, 16, 32, 10
    key = jax.random.PRNGKey(0)

    print(f"== dataset: n={n} d={d}, lattice M={M}")
    data = jax.random.uniform(jax.random.fold_in(key, 0), (n, d))

    # --- theory: the paper's complexity claim -------------------------------
    plan = plan_index(n=n, R1=0.05 * d, R2=0.4 * d, M=M, d=d, family="theta")
    print(f"== theory: P1={plan.P1:.3f} P2={plan.P2:.3f} rho={plan.rho:.3f} "
          f"(query time O(n^{plan.rho:.2f}) < O(n)) -> K={plan.K} L={plan.L}")

    # --- one Index, owning its config ---------------------------------------
    cfg = IndexConfig(d=d, M=M, K=10, L=32, family="theta",
                      max_candidates=512, space=BoundedSpace(0.0, 1.0, float(M)))
    t0 = time.time()
    index = Index.build(jax.random.fold_in(key, 1), data, cfg)
    jax.block_until_ready(index.state.sorted_keys)
    print(f"== built {cfg.L} tables x {cfg.K} hashes in {time.time()-t0:.2f}s "
          f"(O(d) per hash via the paper's §4.2.3 prefix trick)")

    # --- weighted queries: policy = QuerySpec value, not a code path --------
    b = 64
    q = jax.random.uniform(jax.random.fold_in(key, 2), (b, d))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (b, d))) + 0.2

    t0 = time.time()
    res = index.query(q, w, QuerySpec(k=k))
    jax.block_until_ready(res.dists)
    t_alsh = time.time() - t0

    t0 = time.time()
    ref = index.query(q, w, QuerySpec(k=k, mode="exact"))
    jax.block_until_ready(ref.dists)
    t_bf = time.time() - t0

    cand = float(jnp.mean(res.n_candidates))
    print(f"== ALSH:  {t_alsh*1e3:7.1f} ms for {b} queries  "
          f"(examined {cand:.0f}/{n} = {cand/n:.1%} candidates/query)")
    print(f"== exact: {t_bf*1e3:7.1f} ms for {b} queries  (100% scanned)")
    print(f"== recall@{k} = {recall_at_k(res.ids, ref.ids, k):.2f}")

    res_mp = index.query(q, w, QuerySpec(k=k, mode="multiprobe", n_probes=8))
    print(f"== multiprobe (8 probes/table): recall@{k} = "
          f"{recall_at_k(res_mp.ids, ref.ids, k):.2f} — same policy surface, "
          f"fewer tables needed")

    # --- self-describing persistence ----------------------------------------
    with tempfile.TemporaryDirectory() as ckdir:
        index.save(ckdir)
        restored = Index.load(ckdir)  # directory alone — config travels along
        r2 = restored.query(q, w, QuerySpec(k=k))
        assert np.array_equal(np.asarray(r2.ids), np.asarray(res.ids))
        print(f"== save/load round-trip: restored index (n={restored.n}, "
              f"family={restored.config.family!r}) answers bit-identically")

    # --- negative weights (paper abstract: each w_i may be < 0) -------------
    w_neg = jax.random.normal(jax.random.fold_in(key, 4), (b, d))
    res_neg = index.query(q, w_neg, QuerySpec(k=k))
    ref_neg = index.query(q, w_neg, QuerySpec(k=k, mode="exact"))
    print(f"== mixed-sign weights: recall@{k} = "
          f"{recall_at_k(res_neg.ids, ref_neg.ids, k):.2f} "
          f"(harder geometry: near = most-negative distance)")


if __name__ == "__main__":
    main()
