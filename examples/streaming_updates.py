"""Mutable index lifecycle: insert / delete / compact without a rebuild.

    PYTHONPATH=src python examples/streaming_updates.py [--n 20000]

The paper's Theorem-1 index is build-once; this example runs it as a LIVE
structure: a sealed main segment plus a fixed-capacity delta segment for
inserts (hashed with the same tables, so one set of query keys is valid
everywhere) and a tombstone bitmap for deletes. Everything is static-shape,
so the whole insert → delete → query cycle is one compiled program — and
results stay EXACTLY what a fresh build over the surviving rows would
return (same build key ⇒ same tables ⇒ same hashes).
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import BoundedSpace, Index, IndexConfig, QuerySpec, UpdateSpec
from repro.distance import recall_at_k


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    args = ap.parse_args()

    n, d, M, k = args.n, 16, 32, 10
    key = jax.random.PRNGKey(0)
    data = jax.random.uniform(jax.random.fold_in(key, 0), (n, d))

    cfg = IndexConfig(d=d, M=M, K=10, L=32, family="theta",
                      max_candidates=512, space=BoundedSpace(0.0, 1.0, float(M)))
    update = UpdateSpec(delta_capacity=4096, compact_threshold=0.75)

    t0 = time.time()
    index = Index.build(jax.random.fold_in(key, 1), data, cfg, update=update)
    jax.block_until_ready(index.state.sorted_keys)
    t_build = time.time() - t0
    print(f"== built mutable index: n={n} sealed rows + "
          f"{update.delta_capacity} delta slots in {t_build:.2f}s")

    # --- inserts land in the delta segment (no sort, no rebuild) ------------
    m = 2048
    new_rows = jax.random.uniform(jax.random.fold_in(key, 2), (m, d))
    jinsert = jax.jit(lambda ix, rows: ix.insert(rows))
    index, ids = jinsert(index, new_rows)  # warm-up compile
    t0 = time.time()
    index, ids2 = jinsert(index, jax.random.uniform(jax.random.fold_in(key, 3), (m, d)))
    jax.block_until_ready(ids2)
    print(f"== inserted 2x{m} rows (ids {int(ids[0])}..{int(ids2[-1])}); "
          f"steady-state insert: {m/(time.time()-t0):,.0f} rows/s "
          f"(vs full rebuild {t_build:.2f}s)")

    # --- deletes tombstone (ids never come back) ----------------------------
    dead = jnp.concatenate([jnp.arange(100, dtype=jnp.int32), ids[:100]])
    index = index.delete(dead)
    print(f"== deleted {dead.shape[0]} rows (100 sealed + 100 delta); "
          f"live rows: {index.n_live}, delta fill {index.delta_fill}/{update.delta_capacity}")

    # --- queries see one coherent view of both segments ---------------------
    b = 64
    q = jax.random.uniform(jax.random.fold_in(key, 4), (b, d))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 5), (b, d))) + 0.2
    res = index.query(q, w, QuerySpec(k=k))
    exact = index.query(q, w, QuerySpec(k=k, mode="exact"))
    assert not np.isin(np.asarray(dead), np.asarray(res.ids)).any()
    print(f"== query over both segments: recall@{k}="
          f"{recall_at_k(res.ids, exact.ids, k):.2f}, "
          f"candidates/query ~{float(jnp.mean(res.n_candidates)):.0f} "
          f"of {index.n_live} live rows")

    # --- compact: merge delta + survivors into a fresh sealed segment -------
    t0 = time.time()
    index = index.compact()
    jax.block_until_ready(index.state.sorted_keys)
    print(f"== compacted to n={index.n} sealed rows in {time.time()-t0:.2f}s "
          f"(the only operation that sorts; hashes were NOT recomputed)")

    # --- parity: bit-identical to a fresh build over the survivors ----------
    # (demonstrated at a scale where the per-table candidate window C never
    # truncates a bucket: under truncation, the mutated and fresh indexes
    # keep different — equally valid — C-subsets of an oversized bucket)
    ns, caps = 1500, 512
    cfg_s = IndexConfig(d=d, M=M, K=10, L=16, family="theta",
                        max_candidates=ns + caps,
                        space=BoundedSpace(0.0, 1.0, float(M)))
    small = Index.build(jax.random.fold_in(key, 6), data[:ns], cfg_s,
                        update=UpdateSpec(delta_capacity=caps))
    small, sids = small.insert(data[n - 300:n - 100])
    small = small.delete(jnp.concatenate([jnp.arange(40, dtype=jnp.int32), sids[:40]]))
    live = small.live_ids()
    rows = jnp.concatenate([data[:ns], data[n - 300:n - 100]])
    fresh = Index.build(jax.random.fold_in(key, 6), rows[live], cfg_s)
    got = small.query(q, w, QuerySpec(k=k))
    want = fresh.query(q, w, QuerySpec(k=k))
    mapped = np.where(np.asarray(want.ids) >= 0, live[np.asarray(want.ids)], -1)
    assert np.array_equal(np.asarray(got.ids), mapped), "lifecycle parity broken"
    assert np.array_equal(np.asarray(got.dists), np.asarray(want.dists))
    compacted = small.compact()
    for a, b_ in zip(jax.tree_util.tree_leaves(compacted.state),
                     jax.tree_util.tree_leaves(fresh.state)):
        assert np.array_equal(np.asarray(a), np.asarray(b_))
    print("== parity: mutated index == fresh build over survivors, and "
          "compact() == fresh build (bit-identical)")


if __name__ == "__main__":
    main()
