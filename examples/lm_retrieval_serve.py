"""End-to-end serving driver (the paper's kind: a search/serving system):
serve a small LM with batched requests, with ALSH retrieval augmentation on
the decode path (kNN-LM-style — the paper's technique as a first-class
serving feature).

    PYTHONPATH=src python examples/lm_retrieval_serve.py [--arch gemma3-1b]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro import models
from repro.configs import RetrievalConfig, get_bundle, reduced_model
from repro.runtime import retrieval as rt
from repro.runtime.serve_step import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    args = ap.parse_args()

    mcfg = reduced_model(get_bundle(args.arch).model)
    rcfg = RetrievalConfig(datastore_size=8192, d_key=16, K=8, L=12, topk=8,
                           interp_lambda=0.25)
    key = jax.random.PRNGKey(0)
    params = models.init_params(key, mcfg)
    retr = rt.build_datastore(jax.random.fold_in(key, 1), mcfg.d_model,
                              mcfg.vocab_size, rcfg)
    # the datastore is a repro.api Index — config rides with it as one bundle
    icfg = retr.index.config
    print(f"[serve] datastore index: n={retr.index.n} d={icfg.d} "
          f"family={icfg.family!r} K={icfg.K} L={icfg.L}")
    B, S, G = args.batch, args.prompt_len, args.gen_len

    prefill = jax.jit(make_prefill_step(mcfg, cache_len=S + G))
    decode_plain = jax.jit(make_decode_step(mcfg))
    decode_retr = jax.jit(make_decode_step(mcfg, rcfg))

    prompt = jax.random.randint(jax.random.fold_in(key, 2), (B, S), 0, mcfg.vocab_size)
    logits, caches = prefill(params, {"tokens": prompt})
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(tok)
    print(f"[serve] arch={args.arch} (reduced) B={B} prompt={S} gen={G}")

    for name, step, extra in (
        ("plain", decode_plain, ()),
        ("ALSH-retrieval", decode_retr, (retr,)),
    ):
        t = tok
        c = caches
        t0 = time.time()
        outs = []
        for i in range(G):
            batch = {"token": t, "pos": jnp.full((B,), S + i, jnp.int32)}
            _, t, c = step(params, batch, c, *extra)
            outs.append(t)
        jax.block_until_ready(t)
        dt = (time.time() - t0) / G * 1e3
        print(f"[serve] {name:16s}: {dt:6.1f} ms/step | first seq tokens: "
              f"{[int(x[0]) for x in outs[:10]]}")

    print("[serve] retrieval weights ride with each query (paper's w): pass "
          "batch['retr_weights'] to bias which hidden dimensions matter.")


if __name__ == "__main__":
    main()
